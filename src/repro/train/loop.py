"""Fault-tolerant training loop driver.

Wires together: deterministic data pipeline -> (possibly accumulated /
pod-compressed) train step -> async checkpointing -> heartbeat/straggler
monitoring -> elastic restart planning. The loop is pure Python around a
jit'd step, so every policy here is unit-testable without hardware.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step,
                                   restore_checkpoint)
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import HostDataConfig, host_batch
from repro.ft.failures import (FailureEvent, HeartbeatMonitor,
                               StragglerDetector)

__all__ = ["LoopConfig", "TrainLoop", "run_training"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    heartbeat_timeout: float = 300.0
    straggler_factor: float = 1.5
    log_every: int = 10
    grad_accum: int = 1
    seed: int = 17


class TrainLoop:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 loop_cfg: LoopConfig, step_fn: Callable,
                 state: Dict[str, Any],
                 data_cfg: Optional[HostDataConfig] = None,
                 state_shardings: Optional[Any] = None):
        self.cfg = cfg
        self.shape = shape
        self.loop_cfg = loop_cfg
        self.step_fn = step_fn
        self.state = state
        self.data_cfg = data_cfg or HostDataConfig(loop_cfg.seed, 1, 0)
        self.state_shardings = state_shardings
        self.ckpt = (AsyncCheckpointer(loop_cfg.ckpt_dir, loop_cfg.keep_ckpts)
                     if loop_cfg.ckpt_dir else None)
        self.hb = HeartbeatMonitor(self.data_cfg.num_hosts,
                                   loop_cfg.heartbeat_timeout)
        self.straggle = StragglerDetector(
            straggler_factor=loop_cfg.straggler_factor)
        self.metrics_log: List[Dict[str, float]] = []
        self.events: List[FailureEvent] = []

    # -- restart support ------------------------------------------------------
    def maybe_restore(self) -> int:
        """Resume from the newest committed checkpoint; returns start step."""
        if not self.loop_cfg.ckpt_dir:
            return 0
        step = latest_step(self.loop_cfg.ckpt_dir)
        if step is None:
            return 0
        self.state = restore_checkpoint(self.loop_cfg.ckpt_dir, step,
                                        self.state, self.state_shardings)
        return step

    def _batch_for(self, step: int):
        if self.loop_cfg.grad_accum > 1:
            micros = [host_batch(self.cfg, self.shape, self.data_cfg,
                                 step * self.loop_cfg.grad_accum + g)
                      for g in range(self.loop_cfg.grad_accum)]
            return jax.tree.map(lambda *xs: np.stack(xs), *micros)
        return host_batch(self.cfg, self.shape, self.data_cfg, step)

    # -- main -----------------------------------------------------------------
    def run(self, start_step: Optional[int] = None) -> Dict[str, Any]:
        step = self.maybe_restore() if start_step is None else start_step
        while step < self.loop_cfg.total_steps:
            t0 = time.monotonic()
            batch = self._batch_for(step)
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            self.hb.beat(self.data_cfg.host_index)
            self.straggle.record(self.data_cfg.host_index, dt)
            self.events.extend(self.hb.check(step))
            self.events.extend(self.straggle.check(step))
            step += 1
            if step % self.loop_cfg.log_every == 0 or \
                    step == self.loop_cfg.total_steps:
                self.metrics_log.append(
                    {"step": step, "time_s": dt,
                     **{k: float(np.asarray(v)) for k, v in metrics.items()}})
            if self.ckpt and step % self.loop_cfg.ckpt_every == 0:
                tree = dict(self.state)
                self.ckpt.save(step, tree)
        if self.ckpt:
            self.ckpt.save(self.loop_cfg.total_steps, dict(self.state))
            self.ckpt.wait()
        return self.state


def run_training(cfg: ModelConfig, shape: ShapeConfig, loop_cfg: LoopConfig,
                 step_fn: Callable, state: Dict[str, Any], **kw):
    return TrainLoop(cfg, shape, loop_cfg, step_fn, state, **kw).run()
