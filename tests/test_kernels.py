"""Pallas kernel validation: interpret=True vs ref.py oracles, swept over
shapes and dtypes (per-kernel allclose, exactness for integer paths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accum import plan_dot_accumulation
from repro.kernels import ref
from repro.kernels.bitplane_add import bitplane_add_pallas
from repro.kernels.moa_reduce import moa_reduce_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas


# ---------------------------------------------------------------- moa_reduce
@pytest.mark.parametrize("n,rows,cols", [
    (2, 8, 128), (4, 64, 128), (7, 33, 257), (16, 128, 384), (33, 16, 130),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_moa_reduce_shapes_dtypes(n, rows, cols, dtype):
    rng = np.random.default_rng(n * rows + cols)
    if dtype == jnp.int32:
        x = jnp.asarray(rng.integers(-1000, 1000, (n, rows, cols)), dtype)
        acc = jnp.int32
    else:
        x = jnp.asarray(rng.standard_normal((n, rows, cols)), dtype)
        acc = jnp.float32
    got = moa_reduce_pallas(x, bm=64, bn=128, acc_dtype=acc, interpret=True)
    want = ref.moa_reduce_ref(x, acc_dtype=acc)
    if dtype == jnp.int32:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-6, atol=1e-5)


def test_moa_reduce_operand_blocking():
    """bk < N forces cross-grid-step accumulation in the output tile."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((24, 32, 256)), jnp.float32)
    got = moa_reduce_pallas(x, bm=32, bn=128, bk=5, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.moa_reduce_ref(x)),
                               rtol=2e-6, atol=1e-5)


def test_moa_reduce_bf16_accumulates_fp32():
    """bf16 inputs, fp32 accumulation: the fused kernel must not lose the
    small terms that a bf16 chain would (the accumulator-width story)."""
    n = 256
    x = jnp.concatenate([jnp.full((1, 8, 128), 1024.0, jnp.bfloat16),
                         jnp.full((n - 1, 8, 128), 0.25, jnp.bfloat16)])
    got = moa_reduce_pallas(x, acc_dtype=jnp.float32, out_dtype=jnp.float32,
                            interpret=True)
    want = 1024.0 + 0.25 * (n - 1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


# ------------------------------------------------------------- bitplane_add
@pytest.mark.parametrize("n,m_bits,batch", [
    (4, 4, 64), (4, 16, 256), (16, 16, 128), (3, 8, 33), (64, 20, 512),
])
def test_bitplane_add_exact(n, m_bits, batch):
    rng = np.random.default_rng(n + m_bits)
    x = jnp.asarray(rng.integers(0, 2 ** m_bits, (n, batch)), jnp.int32)
    got = bitplane_add_pallas(x, m_bits=m_bits, bb=128, interpret=True)
    want = ref.bitplane_add_ref(x, m_bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitplane_add_paper_example():
    """Fig 12 operands, vectorized across a batch of identical problems."""
    x = jnp.asarray([[0xA], [0xF], [0x1], [0x2]], jnp.int32)
    x = jnp.tile(x, (1, 256))
    got = bitplane_add_pallas(x, m_bits=4, interpret=True)
    assert int(got[0]) == 0x1C and int(got[-1]) == 0x1C


def test_bitplane_add_width_guard():
    with pytest.raises(ValueError):
        bitplane_add_pallas(jnp.zeros((8, 4), jnp.int32), m_bits=30,
                            interpret=True)


# ------------------------------------------------------------- quant_matmul
@pytest.mark.parametrize("m,k,n", [
    (8, 128, 128), (32, 384, 256), (130, 257, 65), (256, 1024, 512),
])
def test_quant_matmul_exact(m, k, n):
    rng = np.random.default_rng(m + k + n)
    x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    got = quant_matmul_pallas(x, w, bm=64, bn=64, interpret=True)
    want = ref.quant_matmul_ref(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quant_matmul_plan_is_binding():
    """The Theorem's block bound is exact: with an emulated narrow
    accumulator, max_block terms never overflow but max_block+1 can."""
    plan = plan_dot_accumulation(1024, acc_bits=18, align=1)
    # worst-case products: (-128)*(-128) = 2^14 each
    worst = 2 ** 14
    assert plan.max_block * worst <= 2 ** 17 - 1 + 1  # fits 18-bit signed
    assert (plan.max_block + 1) * worst > 2 ** 17     # would overflow


def test_quant_matmul_worst_case_no_overflow():
    """All-(-128) inputs at K=8192: partials stay within int32 as planned."""
    k = 8192
    x = jnp.full((4, k), -128, jnp.int8)
    w = jnp.full((k, 4), -128, jnp.int8)
    got = quant_matmul_pallas(x, w, interpret=True)
    assert int(got[0, 0]) == k * 128 * 128
    plan = plan_dot_accumulation(k, acc_bits=32)
    assert plan.exact


# ----------------------------------------------------------- flash attention
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import flash_attention_ref


@pytest.mark.parametrize("b,s,hq,hkv,hd,dt", [
    (2, 256, 4, 2, 64, jnp.float32),      # GQA rep=2
    (1, 128, 8, 8, 128, jnp.float32),     # MHA, aligned head dim
    (2, 256, 6, 2, 80, jnp.bfloat16),     # rep=3, padded head dim (80->128)
    (1, 512, 4, 1, 128, jnp.float32),     # MQA, multi-block q and k
])
def test_flash_attention_matches_ref(b, s, hq, hkv, hd, dt):
    rng = np.random.default_rng(hash((b, s, hq)) % 2 ** 31)
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), dt)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), dt)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), dt)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    ref_out = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_non_causal_and_blocks():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    for bq, bk in ((64, 128), (128, 64), (256, 256)):
        out = flash_attention_pallas(q, k, v, causal=False, block_q=bq,
                                     block_k=bk, interpret=True)
        ref_out = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   rtol=2e-5, atol=2e-5)
