"""Shared symmetric quantization primitives for KV pages and gradients.

One audited implementation serves two consumers:

* **Gradient compression** (:mod:`repro.optim.compression`) — per-tensor
  int8 with a pre-agreed shared scale (:func:`quantize_int8` /
  :func:`dequantize_int8`, re-exported there for backward compatibility).
* **Quantized KV pages** (the serve tier's ``kv_dtype`` knob) — per-row
  symmetric int8/int4 codes with an fp32 scale per (token, head) row
  (:func:`quantize_rows` / :func:`dequantize_rows`).  int4 codes are
  packed two per byte (:func:`pack_int4` / :func:`unpack_int4`) so a page
  pool leaf shrinks 8x vs fp32; the code dtype *is* the bit-width tag
  (``int8`` -> 8-bit, ``uint8`` -> packed 4-bit, :func:`kv_bits`).

The accumulator-width question — can ``page_size`` quantized rows be
summed exactly inside the split-K page combine without overflow — is
answered by the paper's exact carry math, not a worst-case guess:
:func:`kv_carry_budget` instantiates
``repro.core.carry.carry_budget(N=page_size, M=bits, k=2)`` and
:func:`assert_kv_accumulator` enforces at engine build time that the
exact result width (plus a sign bit) fits the int32 carrier, mirroring
the build-time check gradient reduction already performs via
``repro.core.accum.plan_gradient_reduction``.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.carry import CarryBudget, carry_budget

__all__ = [
    "KV_DTYPES", "quantize_int8", "dequantize_int8",
    "quantize_rows", "dequantize_rows", "pack_int4", "unpack_int4",
    "kv_bits", "kv_carry_budget", "assert_kv_accumulator",
]

#: Engine-facing names for the KV page element type.
KV_DTYPES = ("fp32", "int8", "int4")

#: Smallest representable scale: an all-zero row quantizes to all-zero
#: codes with this scale, so dequantization reproduces exact zeros.
_SCALE_FLOOR = 1e-12


def quantize_int8(g: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Symmetric per-tensor int8 with a *shared* (pre-agreed) scale."""
    q = jnp.round(g.astype(jnp.float32) / scale)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8` (fp32 output)."""
    return q.astype(jnp.float32) * scale


def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int8 codes in [-8, 7] two-per-byte along the last axis.

    ``q``: ``(..., D)`` int8 with ``D`` even.  Returns ``(..., D // 2)``
    uint8 — element ``i`` holds codes ``2i`` (low nibble) and ``2i + 1``
    (high nibble), each stored offset-binary (code + 8)."""
    if q.shape[-1] % 2:
        raise ValueError(f"pack_int4 needs an even last axis, "
                         f"got {q.shape[-1]}")
    lo = (q[..., 0::2] + 8).astype(jnp.uint8)
    hi = (q[..., 1::2] + 8).astype(jnp.uint8)
    return lo | (hi << 4)


def unpack_int4(u: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`: ``(..., D/2)`` uint8 -> ``(..., D)``
    int8 codes in [-8, 7]."""
    lo = (u & 0x0F).astype(jnp.int8) - 8
    hi = (u >> 4).astype(jnp.int8) - 8
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(u.shape[:-1] + (u.shape[-1] * 2,))


def kv_bits(codes) -> int:
    """Bit width encoded by a KV code array's dtype: ``int8`` -> 8,
    ``uint8`` (two packed nibbles) -> 4."""
    dt = jnp.dtype(codes.dtype if hasattr(codes, "dtype") else codes)
    if dt == jnp.dtype(jnp.int8):
        return 8
    if dt == jnp.dtype(jnp.uint8):
        return 4
    raise ValueError(f"not a KV code dtype: {dt} (expected int8 or uint8)")


def quantize_rows(x: jnp.ndarray, bits: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row symmetric quantization over the LAST axis.

    Each row (one token's features for one head) gets its own fp32 scale
    ``amax(|row|) / qmax``, so freshly decoded rows can be written into a
    quantized page pool one at a time — no page-wide requantization on
    append, and copy-on-write moves codes and scales together.

    Args:
      x: ``(..., D)`` float rows.
      bits: 8 (int8 codes in [-127, 127]) or 4 (codes in [-7, 7], packed
        two per byte — ``D`` must be even).

    Returns:
      ``(codes, scale)``: codes ``(..., D)`` int8 for 8-bit or
      ``(..., D // 2)`` uint8 for 4-bit, and ``scale`` ``(...,)`` fp32.
    """
    if bits not in (8, 4):
        raise ValueError(f"bits must be 8 or 4, got {bits}")
    qmax = 127 if bits == 8 else 7
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1)
    scale = jnp.maximum(amax / qmax, _SCALE_FLOOR)
    q = jnp.clip(jnp.round(x32 / scale[..., None]), -qmax, qmax)
    q = q.astype(jnp.int8)
    return (pack_int4(q) if bits == 4 else q), scale


def dequantize_rows(codes: jnp.ndarray, scale: jnp.ndarray,
                    out_dtype) -> jnp.ndarray:
    """Inverse of :func:`quantize_rows`; bit width is read off
    ``codes.dtype`` (:func:`kv_bits`).

    Args:
      codes: ``(..., D)`` int8 or ``(..., D/2)`` packed uint8 codes.
      scale: ``(...,)`` per-row fp32 scales.
      out_dtype: dtype of the dequantized rows (the attention compute
        dtype — scores/softmax stay fp32 downstream regardless).
    """
    if kv_bits(codes) == 4:
        codes = unpack_int4(codes)
    out = codes.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
    return out.astype(out_dtype)


def kv_carry_budget(page_size: int, bits: int) -> CarryBudget:
    """The paper's exact width plan for summing one KV page's quantized
    rows: ``carry_budget(N=page_size, M=bits, k=2)`` — ``page_size``
    operands of ``bits`` binary digits each."""
    return carry_budget(page_size, bits, 2)


def assert_kv_accumulator(page_size: int, bits: int,
                          acc_bits: int = 32) -> CarryBudget:
    """Build-time audit that a page-wide sum of quantized magnitudes fits
    the integer carrier.

    The exact worst case is ``result_digits`` magnitude bits plus one sign
    bit (symmetric codes are signed); raises ``ValueError`` when that
    exceeds ``acc_bits``, otherwise returns the :class:`CarryBudget` so
    callers can log the audited widths."""
    b = kv_carry_budget(page_size, bits)
    need = b.result_digits + 1
    if need > acc_bits:
        raise ValueError(
            f"page_size={page_size} x int{bits} rows need {need} "
            f"accumulator bits ({b.result_digits} magnitude + sign), which "
            f"overflows the int{acc_bits} carrier — shrink the page size")
    return b
