"""Quickstart: the paper's multi-operand adder stack in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Walks the whole vertical: carry theory -> bit-exact adders -> Theorem-planned
integer accumulation -> Lemma-3 execution planning -> one sharded train step.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.core import moa
from repro.core.accum import max_operands_exact, plan_dot_accumulation
from repro.core.carry import carry_budget, column_transition_N
from repro.core.planner import UnitSpec, serial_beats_parallel
from repro.launch.inputs import make_batch
from repro.optim.adamw import AdamWConfig
from repro.train.state import build_train_step, init_train_state

# -- 1. carry theory (paper §2) ---------------------------------------------
b = carry_budget(N=16, M=16, k=2)
print(f"16 operands x 16 bits: carry <= {b.carry_value_bound} (Theorem), "
      f"exact worst carry {b.carry_value_exact}, result width "
      f"{b.result_digits} bits (bound {b.result_digits_bound})")
print(f"column transition (k=2, M=3, p=4): carry widens at N = "
      f"{column_transition_N(3, 4, 2)} (paper Table 3: 19)")

# -- 2. bit-exact adders (paper §4-§7) --------------------------------------
ops = jnp.asarray([[0xA234, 0xFFFF, 0x0A2D, 0xFF7F]], jnp.int32)
res, clocks = moa.serial_add(ops, 16)
print(f"serial 4x16 adder: sum={int(res[0]):#x} in {clocks} clocks "
      f"(paper Fig 14: 0x2ABDF, 17 clocks)")
big = jnp.asarray(np.arange(16, dtype=np.int32)[None] * 1000)
res16 = moa.reconfigured_add(big, 16)
print(f"reconfigured 16-operand adder: {int(res16[0])} == {int(big.sum())}")

# -- 3. the Theorem applied to TPU integer paths ----------------------------
plan = plan_dot_accumulation(k_total=8192, lhs_bits=8, rhs_bits=8,
                             acc_bits=32)
print(f"int8 matmul K=8192: exact int32 accumulation in blocks of "
      f"{plan.block} ({plan.num_blocks} blocks, spill {plan.spill_bits} bits)")
print(f"int8 gradient all-reduce stays exact up to "
      f"{max_operands_exact(32, 7, signed=True)} replicas")

# -- 4. Lemma 3: serial vs parallel execution units --------------------------
serial = UnitSpec(area=1, clocks_per_op=17)
parallel = UnitSpec(area=20, clocks_per_op=1)
print(f"Lemma 3 (R_A=20 > R_T=17): serial set wins -> "
      f"{serial_beats_parallel(serial, parallel)}")

# -- 5. one train step of an assigned architecture ---------------------------
cfg = get_config("llama3.2-3b").reduced(dtype=jnp.float32)
shape = ShapeConfig("qs", seq_len=32, global_batch=4, kind="train")
state = init_train_state(cfg, jax.random.key(0))
step = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3)))
state, metrics = step(state, make_batch(cfg, shape, seed=0))
print(f"one train step of reduced llama3.2-3b: loss={float(metrics['loss']):.3f}")
print("quickstart OK")
