"""The 4->3 ones-count LUT (paper Figs 3/4) and the §10 gate-cost models.

Two deliverables live here:

1. The LUT itself — the I/O map of Fig 3 — as both a Python table and a JAX
   gather, plus the explicit gate-level netlist of Fig 4 (ones-count logic)
   evaluated bit-by-bit so tests can prove the netlist == the table.

2. The gate-delay / gate-area cost models used in §10 to compare LUT-based
   multi-operand adders with conventional Carry-Look-Ahead (CLA) adders
   (Figs 16-18). The paper gives the anchor constants (LUT: 4-gate delay /
   25-gate area for the 1-bit 4->3 unit; 4-bit CLA: 9-gate delay / 50-gate
   area, citing [2013 Jovanovic]) and states the larger structures are
   "extended" from these units; the extension rules below are reconstructed
   from §5/§7 (radix-4 LUT trees; binary CLA trees) and documented inline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "LUT4_TABLE",
    "lut4_lookup",
    "lut4_netlist",
    "popcount_tree",
    "LUT_DELAY_GATES",
    "LUT_AREA_GATES",
    "CLA4_DELAY_GATES",
    "CLA4_AREA_GATES",
    "GateCost",
    "lut_parallel_adder_cost",
    "cla_adder_cost",
    "cla_tree_cost",
    "lut_tree_cost",
    "performance_advantage",
]

# ---------------------------------------------------------------------------
# The 4->3 LUT (Fig 3): input = 4 column bits, output = ones count (0..4)
# ---------------------------------------------------------------------------

#: Fig 3 I/O map: index = packed 4 input bits (b3 b2 b1 b0), value = popcount.
LUT4_TABLE: np.ndarray = np.array([bin(i).count("1") for i in range(16)],
                                  dtype=np.int32)


def lut4_lookup(packed: jnp.ndarray) -> jnp.ndarray:
    """Vectorized Fig-3 LUT: ``packed`` holds 4-bit codes in [0, 16)."""
    return jnp.take(jnp.asarray(LUT4_TABLE), packed, axis=0)


def lut4_netlist(b: jnp.ndarray) -> jnp.ndarray:
    """Fig 4 one's-count *gate netlist*, evaluated on the last axis of 4 bits.

    Structure (two-input gates, longest path 4 gates):
      half-add pairs:  s0 = b0^b1, c0 = b0&b1 ; s1 = b2^b3, c1 = b2&b3
      merge sums:      z0 = s0^s1, m  = s0&s1
      merge carries:   t  = c0^c1, z2p = c0&c1
      weight-2 column: z1 = t^m,  k  = t&m
      weight-4:        z2 = z2p | k
    Output value = z0 + 2*z1 + 4*z2  == popcount(b).
    """
    b = b.astype(jnp.int32)
    b0, b1, b2, b3 = b[..., 0], b[..., 1], b[..., 2], b[..., 3]
    s0, c0 = b0 ^ b1, b0 & b1
    s1, c1 = b2 ^ b3, b2 & b3
    z0, m = s0 ^ s1, s0 & s1
    t, z2p = c0 ^ c1, c0 & c1
    z1, kk = t ^ m, t & m
    z2 = z2p | kk
    return z0 + 2 * z1 + 4 * z2


def popcount_tree(bits: jnp.ndarray) -> jnp.ndarray:
    """Hierarchical LUT popcount over the last axis (any N): groups of 4 go
    through the 4->3 unit, partial counts are added pairwise — the paper's
    'hierarchical implementations with several levels of LUTs' (§3.3)."""
    n = bits.shape[-1]
    pad = (-n) % 4
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1)
    grp = bits.reshape(bits.shape[:-1] + (-1, 4))
    counts = lut4_netlist(grp)          # (..., n/4) partial ones-counts
    return jnp.sum(counts, axis=-1)


# ---------------------------------------------------------------------------
# §10 gate-cost anchors
# ---------------------------------------------------------------------------

LUT_DELAY_GATES = 4     # Fig 4 longest path
LUT_AREA_GATES = 25     # §10: "overall area of 25 gates"
CLA4_DELAY_GATES = 9    # §10, 4-bit two-operand CLA [2013 Jovanovic]
CLA4_AREA_GATES = 50


@dataclass(frozen=True)
class GateCost:
    delay_gates: float
    area_gates: float

    def __add__(self, other: "GateCost") -> "GateCost":
        return GateCost(self.delay_gates + other.delay_gates,
                        self.area_gates + other.area_gates)


def lut_parallel_adder_cost(n_operands: int, m_bits: int) -> GateCost:
    """Cost of one combinatorial LUT-based ``n_operands`` x ``m_bits`` adder.

    Reconstruction: the Fig-7 4x4 unit has one level of per-column LUTs and a
    shifted-merge level; its longest path is 4 LUTs (16 gates) with area
    ~ (2*M - 1) LUT units. For N > 4 operands a radix-4 tree of such units is
    used (§7); level l handles words of (m_bits + 2*(l-1)) bits, since each
    4-operand stage widens the word by 2 bits (Theorem: carry <= 3 -> 2 bits).
    """
    if n_operands < 2:
        return GateCost(0.0, 0.0)
    delay = 0.0
    area = 0.0
    remaining = n_operands
    width = m_bits
    while remaining > 1:
        groups = math.ceil(remaining / 4)
        # Longest path in one 4xW unit is 4 LUTs irrespective of W (Fig 7):
        # column LUTs operate in parallel and the shifted merge is a fixed
        # 3-LUT + half-adder chain.
        delay += LUT_DELAY_GATES * 4
        area += groups * (LUT_AREA_GATES * (2 * width - 1) + 5)
        remaining = groups
        width += 2  # each stage adds 2 carry bits (4-operand carry <= 3)
    return GateCost(delay, area)


def cla_adder_cost(m_bits: int) -> GateCost:
    """Two-operand M-bit adder built from cascaded 4-bit CLA blocks:
    delay = 9 + 4*(blocks-1) (carry ripples between blocks), area = 50/block."""
    blocks = math.ceil(m_bits / 4)
    return GateCost(CLA4_DELAY_GATES + 4 * (blocks - 1),
                    CLA4_AREA_GATES * blocks)


def cla_tree_cost(n_operands: int, m_bits: int) -> GateCost:
    """N-operand addition from a binary tree of two-operand CLAs (the §1
    'tree of adders' baseline): ceil(log2 N) levels, N-1 adders, word width
    growing by 1 bit per level (2-operand carry = 1)."""
    if n_operands < 2:
        return GateCost(0.0, 0.0)
    delay = 0.0
    area = 0.0
    remaining = n_operands
    width = m_bits
    while remaining > 1:
        pairs = remaining // 2
        unit = cla_adder_cost(width)
        delay += unit.delay_gates
        area += pairs * unit.area_gates
        remaining = remaining - pairs  # odd operand passes through
        width += 1
    return GateCost(delay, area)


def lut_tree_cost(n_operands: int, m_bits: int) -> GateCost:
    """Alias with the §7 radix-4 reconfiguration framing."""
    return lut_parallel_adder_cost(n_operands, m_bits)


def performance_advantage(n_operands: int, m_bits: int) -> float:
    """Eqn (22): d_g(CLA) / d_g(LUT) — >1 means the LUT adder is faster."""
    cla = cla_tree_cost(n_operands, m_bits)
    lut = lut_tree_cost(n_operands, m_bits)
    if lut.delay_gates == 0:
        return float("inf")
    return cla.delay_gates / lut.delay_gates
