"""Continuous-batching scheduler: admit / decode / retire / evict, SLO-aware.

Pure host-side Python — no jax — so scheduling policy is unit-testable
without compiling a model.  The engine asks three questions every step:

1. ``admissions()`` — which pending requests go into which free slots now
   (chunked prefill happens per admission);
2. after the batched decode step, ``on_decode(tokens)`` — append one token
   to every live request, retire the finished ones, free their slots;
3. ``has_work`` — is anything pending or live.

Short and long requests share every decode step: a slot freed by a finished
request is refilled on the next ``admissions()`` call while the remaining
slots keep decoding (slot refill mid-flight).  ``evict()`` preempts a live
request back to the pending queue — its re-admission re-prefills prompt +
tokens generated so far, so no output is lost.

**SLO-aware admission** (this tier's policy, replacing blind FIFO): a
request may carry a latency SLO (``slo_ms``, wall time from submission to
completion).  The scheduler keeps a cost model — an engine-fed estimate of
per-chunk prefill time and per-step decode time — and orders admission by
earliest deadline first among SLO'd requests (no-SLO requests follow, in
FIFO order).  ``eviction_candidate()`` picks the live request that best
survives a re-queue (largest post-requeue slack — no-SLO requests are
preferred victims since they cannot miss), and ``maybe_preempt()`` triggers
an eviction only when it actually rescues an at-risk pending request:
the pending request still meets its deadline if admitted *now* but not if
it waits for a natural slot release, and the victim still meets its own
SLO after the re-queue.
"""
from __future__ import annotations

import itertools
import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Callable, Deque, Dict, List, Optional,
                    Sequence, Tuple)

if TYPE_CHECKING:  # sampling imports jax; keep this module jax-free
    from repro.serve.sampling import SamplingParams

__all__ = ["Request", "Scheduler", "DegradeLadder"]

_rid_counter = itertools.count()


@dataclass
class Request:
    """One generation request plus its runtime bookkeeping.

    Args:
      prompt: token ids to condition on.
      max_new: generation budget (tokens sampled after the prompt).
      rid: request id (auto-assigned, monotonic per process).
      eos_id: optional stop token — generation retires on sampling it.
      sampling: per-request :class:`~repro.serve.sampling.SamplingParams`
        (``None`` = greedy argmax, the PR 2 behaviour).
      slo_ms: optional completion-latency SLO in milliseconds, measured
        from submission; drives admission order and eviction choice.
    """

    prompt: Sequence[int]
    max_new: int
    rid: int = field(default_factory=lambda: next(_rid_counter))
    eos_id: Optional[int] = None
    sampling: Optional["SamplingParams"] = None
    slo_ms: Optional[float] = None

    # runtime state (owned by the scheduler/engine)
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    pos: int = 0                # tokens currently in the slot's cache
    submit_t: Optional[float] = None
    finish_t: Optional[float] = None
    slo_met: Optional[bool] = None
    #: why the scheduler shed this request instead of serving it (``None``
    #: for every served request) — a shed request is *retired with a
    #: reason*, never silently dropped: it lands in ``finished`` like any
    #: other, distinguishable by this field
    shed_reason: Optional[str] = None

    @property
    def context(self) -> List[int]:
        """Tokens to prefill on (re-)admission: prompt + already generated."""
        return list(self.prompt) + self.generated

    @property
    def remaining(self) -> int:
        """Tokens still to generate before hitting ``max_new``."""
        return self.max_new - len(self.generated)

    @property
    def done(self) -> bool:
        """True once ``eos_id`` was sampled or the budget is exhausted."""
        if self.generated and self.eos_id is not None \
                and self.generated[-1] == self.eos_id:
            return True
        return self.remaining <= 0


class Scheduler:
    """Slot scheduler over a shared decode batch, with an SLO admission tier.

    Args:
      max_slots: decode batch width (concurrent requests).
      max_seq: per-slot cache capacity (context + generated tokens).
      prefill_chunk: the engine's max prefill-dispatch size; used by the
        cost model to estimate how many chunked-prefill dispatches a
        pending request needs.
      clock: monotonic time source (injectable for deterministic tests).
      reuse_probe: optional callable mapping a request's context tokens to
        the number of leading tokens already resident in some slot's
        (refcounted) pages — the engine wires this to the prefix trie.
        The cost model then prices only the *non-resident* span of a
        (re-)prefill, so eviction and preemption decisions consult the
        page refcounts: a victim whose prefix is shared re-admits almost
        for free and is preferred over one that would re-prefill from
        scratch.
    """

    def __init__(self, max_slots: int, max_seq: int, *,
                 prefill_chunk: int = 32, mesh_shards: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 reuse_probe: Optional[Callable[[Sequence[int]], int]] = None):
        # knob validation (e.g. max_slots >= 1) lives in
        # repro.serve.config.EngineConfig.validate, the one place every
        # consumer goes through — see from_config
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.prefill_chunk = max(1, prefill_chunk)
        #: device shards the slot batch splits into (1 = single-device);
        #: admission balances live load across shards (see free_slots)
        self.mesh_shards = max(1, mesh_shards)
        self.slots_per_shard = max_slots // self.mesh_shards
        self.clock = clock
        self.reuse_probe = reuse_probe
        self.pending: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        # engine-fed cost model (EWMA of measured times; 0 = unknown yet)
        self.est_chunk_s: float = 0.0
        self.est_step_s: float = 0.0
        #: mean tokens a decode step emits per live slot (1.0 for classic
        #: sequential decode; > 1 under speculative multi-token decode,
        #: where accepted drafts make one step worth several tokens)
        self.est_tokens_per_step: float = 1.0
        #: engine-fed per-slot tokens-per-step rates (speculative decode:
        #: each slot's own accept-rate EWMA makes its expected rate — a
        #: repetitive slot drafting well and a cold slot rejecting
        #: everything can differ severalfold, and pricing both at the
        #: batch mean misranks eviction/preemption).  Missing slots fall
        #: back to the batch-mean ``est_tokens_per_step``; a tree decode
        #: step prices exactly like a chain step here (both are one
        #: dispatch), only its expected emitted-token count differs.
        self.slot_tokens_per_step: Dict[int, float] = {}
        self.slo_met_count = 0
        self.slo_missed_count = 0
        #: requests retired unserved by :meth:`shed_hopeless`
        self.shed_count = 0
        #: tokens generated by retired requests that did NOT miss their
        #: SLO (met it, or carried none) — the numerator of goodput
        self.goodput_tokens = 0
        #: optional engine-fed probe mapping a live slot to how many
        #: physical pages releasing its row would actually free (pages
        #: shared by prefix or content dedup free nothing until the last
        #: referent drops them).  Eviction tie-breaking consults it so a
        #: victim whose pages are all shared — ~0 reclaim benefit — is
        #: not preferred over one whose eviction genuinely frees memory.
        self.freed_probe: Optional[Callable[[int], int]] = None

    @classmethod
    def from_config(cls, config, *,
                    clock: Callable[[], float] = time.monotonic,
                    reuse_probe: Optional[Callable[[Sequence[int]], int]]
                    = None) -> "Scheduler":
        """Build a scheduler from an (already validated)
        :class:`~repro.serve.config.EngineConfig`: ``max_slots``,
        ``max_seq``, ``prefill_chunk`` and ``mesh_shards`` are read from
        ``config``; ``clock`` and ``reuse_probe`` pass through to the
        constructor."""
        return cls(config.max_slots, config.max_seq,
                   prefill_chunk=config.prefill_chunk,
                   mesh_shards=getattr(config, "mesh_shards", 1),
                   clock=clock, reuse_probe=reuse_probe)

    # ----------------------------------------------------------- cost model
    def update_cost_model(self, chunk_s: Optional[float] = None,
                          step_s: Optional[float] = None,
                          tokens_per_step: Optional[float] = None) -> None:
        """Feed measured service times: ``chunk_s`` is the engine's current
        estimate of one prefill-chunk dispatch, ``step_s`` of one batched
        decode step, ``tokens_per_step`` of how many tokens one step emits
        per live slot (> 1 under speculative decode — without it, EDF and
        preemption decisions would overprice every speculative request's
        remaining decode by the accept rate).  Pass ``None`` to leave any
        of them unchanged."""
        if chunk_s is not None:
            self.est_chunk_s = float(chunk_s)
        if step_s is not None:
            self.est_step_s = float(step_s)
        if tokens_per_step is not None:
            self.est_tokens_per_step = max(1.0, float(tokens_per_step))

    def est_decode_s(self, n_tokens: int,
                     tokens_per_step: Optional[float] = None) -> float:
        """Estimated wall time to decode ``n_tokens`` for one request under
        the current cost model: steps needed at the measured tokens-per-step
        rate (``tokens_per_step`` overrides the batch mean — callers with a
        per-slot rate pass it), each costing one batched-step time."""
        if n_tokens <= 0:
            return 0.0
        rate = (self.est_tokens_per_step if tokens_per_step is None
                else max(1.0, float(tokens_per_step)))
        return math.ceil(n_tokens / rate) * self.est_step_s

    def est_service_s(self, req: Request) -> float:
        """Estimated remaining service time of ``req`` if admitted now:
        chunked prefill of its context plus its remaining decode budget,
        under the current cost model (0 while the model is cold).

        With a ``reuse_probe`` configured, the resident prefix of the
        context is priced at zero — a prefix-cache hit shares those pages
        by reference instead of prefilling them.  A live request whose slot
        has an entry in :attr:`slot_tokens_per_step` prices its decode at
        its own measured rate instead of the batch mean."""
        ctx_len = max(1, len(req.context))
        to_prefill = ctx_len
        if self.reuse_probe is not None:
            to_prefill = max(1, ctx_len - int(self.reuse_probe(req.context)))
        chunks = math.ceil(to_prefill / self.prefill_chunk)
        rate = (self.slot_tokens_per_step.get(req.slot)
                if req.slot is not None else None)
        return chunks * self.est_chunk_s \
            + self.est_decode_s(req.remaining, rate)

    def deadline(self, req: Request) -> Optional[float]:
        """Absolute completion deadline of ``req`` on the scheduler clock,
        or ``None`` for a request without an SLO."""
        if req.slo_ms is None or req.submit_t is None:
            return None
        return req.submit_t + req.slo_ms / 1e3

    def slack_s(self, req: Request, now: Optional[float] = None) -> float:
        """Deadline slack of ``req`` at time ``now``: seconds to spare if
        its remaining service started immediately (+inf without an SLO;
        negative means the SLO is already unattainable)."""
        dl = self.deadline(req)
        if dl is None:
            return math.inf
        if now is None:
            now = self.clock()
        return dl - now - self.est_service_s(req)

    # -------------------------------------------------------------- submit
    def submit(self, req: Request) -> Request:
        """Queue ``req`` for admission (validates that its context plus at
        least one generated token fits ``max_seq``) and stamp its
        submission time. Returns the same request."""
        if len(req.context) + 1 > self.max_seq:
            raise ValueError(
                f"request {req.rid}: context {len(req.context)} + 1 token "
                f"exceeds max_seq={self.max_seq}")
        if req.submit_t is None:
            req.submit_t = self.clock()
        self.pending.append(req)
        return req

    # ---------------------------------------------------------- admissions
    def shard_of_slot(self, slot: int) -> int:
        """The mesh shard holding ``slot`` (0 on single-device engines)."""
        return slot // self.slots_per_shard

    def shard_loads(self) -> List[int]:
        """Live-request count per mesh shard (the per-shard occupancy the
        cost model and admission balancing consult; ``[len(active)]`` on
        a single-device engine)."""
        loads = [0] * self.mesh_shards
        for s in self.active:
            loads[self.shard_of_slot(s)] += 1
        return loads

    def free_slots(self) -> List[int]:
        """Slot indices not currently bound to a live request, in the
        order admission should fill them.

        Single-device engines keep the classic ascending order.  Sharded
        engines interleave shards, least-loaded first — the k-th free
        slot of every shard before any shard's (k+1)-th — so consecutive
        admissions land on different devices and per-shard occupancy
        stays balanced (idle lanes on one device while another queues
        would waste whole-device throughput)."""
        free = [s for s in range(self.max_slots) if s not in self.active]
        if self.mesh_shards > 1:
            loads = self.shard_loads()
            rank: Dict[int, int] = {}
            keys = {}
            for s in free:
                sh = self.shard_of_slot(s)
                keys[s] = (rank.get(sh, 0), loads[sh], sh)
                rank[sh] = rank.get(sh, 0) + 1
            free.sort(key=lambda s: keys[s])
        return free

    def admission_order(self) -> List[Request]:
        """Pending requests in admission-policy order: earliest deadline
        first for SLO'd requests, then no-SLO requests in FIFO order (the
        sort is stable, so with no SLOs anywhere this *is* FIFO — and an
        evicted request re-queued at the front keeps its priority)."""
        return sorted(self.pending,
                      key=lambda r: (self.deadline(r) is None,
                                     self.deadline(r) or 0.0))

    def admissions(self) -> List[Tuple[int, Request]]:
        """Pair waiting requests with free slots in policy order. The
        caller performs the actual prefill, then each request is live in
        its slot."""
        pairs = []
        for slot, req in zip(self.free_slots(), self.admission_order()):
            req.slot = slot
            req.pos = 0
            self.active[slot] = req
            pairs.append((slot, req))
        if pairs:
            admitted = {req.rid for _, req in pairs}
            self.pending = deque(r for r in self.pending
                                 if r.rid not in admitted)
        return pairs

    # -------------------------------------------------------------- decode
    def on_prefill(self, req: Request, first_token: int) -> None:
        """Record ``req``'s prefill result: the cache holds its context,
        plus ``first_token`` sampled from the prefill logits."""
        req.pos = len(req.context)
        req.generated.append(int(first_token))
        self._maybe_retire(req)

    def on_decode(self, tokens: Dict[int, int]) -> List[Request]:
        """Advance every live slot by its sampled token (``tokens`` maps
        slot -> token id); returns the requests that finished this step
        (their slots are free again)."""
        return self.on_decode_tokens({s: [t] for s, t in tokens.items()})

    def on_decode_tokens(self, tokens: Dict[int, Sequence[int]]
                         ) -> List[Request]:
        """Advance every live slot by the 1..K+1 tokens one (speculative)
        decode step emitted for it; returns the requests that finished.
        Appending stops at retirement (eos / budget / capacity) — the
        engine already truncates to the retire point, this is the
        belt-and-braces guard for the invariant ``pos == len(context) - 1``.
        """
        done = []
        for slot, toks in tokens.items():
            req = self.active.get(slot)
            if req is None:
                continue
            for tok in toks:
                req.generated.append(int(tok))
                req.pos += 1
                if self._maybe_retire(req):
                    done.append(req)
                    break
        return done

    def _maybe_retire(self, req: Request) -> bool:
        # the next decode would write cache position req.pos; retire when
        # the cache is full instead
        hit_cap = req.pos >= self.max_seq
        if req.done or hit_cap:
            if req.slot in self.active:
                del self.active[req.slot]
                self.slot_tokens_per_step.pop(req.slot, None)
            req.slot = None
            req.finish_t = self.clock()
            if req.slo_ms is not None and req.submit_t is not None:
                req.slo_met = ((req.finish_t - req.submit_t) * 1e3
                               <= req.slo_ms)
                if req.slo_met:
                    self.slo_met_count += 1
                else:
                    self.slo_missed_count += 1
            if req.slo_met is not False:
                self.goodput_tokens += len(req.generated)
            self.finished.append(req)
            return True
        return False

    # ---------------------------------------------------------------- shed
    def slo_pressure(self, now: Optional[float] = None) -> float:
        """Fraction of SLO'd work (pending + active) whose deadline is at
        risk under the current cost model as of ``now`` (default: the
        scheduler clock): slack below one batched decode step of
        headroom.  0.0 with no SLO'd requests anywhere — the degrade
        ladder's input signal."""
        if now is None:
            now = self.clock()
        slod = [r for r in itertools.chain(self.pending,
                                           self.active.values())
                if self.deadline(r) is not None]
        if not slod:
            return 0.0
        at_risk = sum(1 for r in slod
                      if self.slack_s(r, now) < self.est_step_s)
        return at_risk / len(slod)

    def shed_hopeless(self, now: Optional[float] = None,
                      reason: str = "overload: SLO unattainable"
                      ) -> List[Request]:
        """Retire-with-reason every *pending* request whose SLO is already
        unattainable as of ``now`` (default: the scheduler clock) —
        negative slack even if admitted immediately — the
        lowest-value work under overload: serving it spends slots without
        earning goodput, and EDF would admit it *first* (earliest
        deadline).  Each shed request lands in ``finished`` with
        ``shed_reason`` set and counts as an SLO miss; live requests are
        never shed.  Returns the shed requests."""
        if now is None:
            now = self.clock()
        doomed = [r for r in self.pending
                  if self.deadline(r) is not None
                  and self.slack_s(r, now) < 0.0]
        if not doomed:
            return []
        dropped = {r.rid for r in doomed}
        self.pending = deque(r for r in self.pending
                             if r.rid not in dropped)
        for req in doomed:
            req.shed_reason = reason
            req.finish_t = now
            req.slo_met = False
            self.slo_missed_count += 1
            self.shed_count += 1
            self.finished.append(req)
        return doomed

    # --------------------------------------------------------------- evict
    def evict(self, slot: int) -> Request:
        """Preempt the live request in ``slot`` back to the head of the
        pending queue. Re-admission re-prefills prompt + generated, so the
        request continues seamlessly."""
        req = self.active.pop(slot)
        req.slot = None
        req.pos = 0
        self.slot_tokens_per_step.pop(slot, None)
        self.pending.appendleft(req)
        return req

    def eviction_candidate(self, now: Optional[float] = None
                           ) -> Optional[int]:
        """The slot whose request best survives a re-queue at time ``now``:
        largest post-requeue slack (re-prefilling its full context plus its
        remaining decode budget still beats its deadline).  No-SLO requests
        have infinite slack, so they are preferred victims.  Ties prefer
        the slot whose eviction actually frees pages (``freed_probe`` —
        a victim whose pages are all prefix- or dedup-shared reclaims
        nothing, so evicting it is pure re-prefill waste), then the
        request with the least generated progress. ``None`` when nothing
        is active."""
        if not self.active:
            return None
        if now is None:
            now = self.clock()
        probe = self.freed_probe or (lambda s: 0)
        return max(self.active,
                   key=lambda s: (self.slack_s(self.active[s], now),
                                  probe(s),
                                  -len(self.active[s].generated)))

    def maybe_preempt(self, now: Optional[float] = None) -> Optional[int]:
        """Decide whether evicting one live request would rescue an
        at-risk pending one; returns the victim slot or ``None``.

        Preempts only when (measured at time ``now``): every slot is busy;
        the most urgent pending request meets its SLO if admitted
        immediately but not after waiting for the earliest natural slot
        release; and the victim still meets its own SLO after the re-queue.
        """
        if not self.pending or len(self.active) < self.max_slots:
            return None
        if now is None:
            now = self.clock()
        # most urgent among the still-savable: a request whose deadline is
        # already unattainable (slack < 0) must not shadow one a preemption
        # could actually rescue
        urgent = min((r for r in self.pending
                      if self.deadline(r) is not None
                      and self.slack_s(r, now) >= 0.0),
                     key=lambda r: self.slack_s(r, now), default=None)
        if urgent is None:
            return None
        est_wait = min((self.est_decode_s(
                            r.remaining, self.slot_tokens_per_step.get(s))
                        for s, r in self.active.items()), default=0.0)
        if self.slack_s(urgent, now) >= est_wait:
            return None                       # not at risk: waiting is fine
        victim = self.eviction_candidate(now)
        if victim is None:
            return None
        if self.slack_s(self.active[victim], now) < 0.0:
            return None                       # re-queue would break its SLO
        return victim

    # --------------------------------------------------------------- state
    @property
    def has_work(self) -> bool:
        """True while anything is pending or live."""
        return bool(self.pending or self.active)

    @property
    def occupancy(self) -> float:
        """Fraction of decode-batch slots currently live."""
        return len(self.active) / self.max_slots


class DegradeLadder:
    """Hysteretic overload controller: which knob to give up next.

    Pure host-side state machine (no clock of its own — the engine feeds
    it one :meth:`Scheduler.slo_pressure` observation per step), stepping
    through four levels in a fixed, monotone order:

    ======  ==============  ================================================
    level   name            engine effect
    ======  ==============  ================================================
    0       ``normal``      every knob at its configured value
    1       ``spec_off``    speculative decode suspended (drafting +
                            K+1-wide verification is wasted work when
                            accept rates drop under adversarial traffic)
    2       ``small_chunks``  prefill dispatches capped at the smallest
                            shape bucket (cheapest marginal admission)
    3       ``shed``        pending requests whose SLO is already
                            unattainable are retired-with-reason
    ======  ==============  ================================================

    Level changes are **hysteretic**: pressure above ``hi`` steps up one
    level per observation (pressure is re-measured between steps, so a
    sustained flat overload climbs 0→1→2→3 and *stays* — no oscillation);
    stepping down requires ``recover_steps`` consecutive observations
    below ``lo``, and the calm counter resets on every excursion above it.
    Every degraded level keeps tokens bit-exact: spec on/off and prefill
    chunking are output-invariant, and shed requests emit nothing.
    """

    #: the levels, in the order the ladder gives things up
    NORMAL, SPEC_OFF, SMALL_CHUNKS, SHED = 0, 1, 2, 3
    LEVEL_NAMES = ("normal", "spec_off", "small_chunks", "shed")

    def __init__(self, *, hi: float = 0.5, lo: float = 0.2,
                 recover_steps: int = 8):
        """``hi``/``lo`` are the step-up / step-down pressure thresholds
        (``lo < hi`` — the dead band between them holds the current
        level); ``recover_steps`` consecutive calm observations are
        required per step down."""
        if not 0.0 <= lo < hi <= 1.0:
            raise ValueError(
                f"need 0 <= lo < hi <= 1, got lo={lo}, hi={hi}")
        if recover_steps < 1:
            raise ValueError(
                f"recover_steps must be >= 1, got {recover_steps}")
        self.hi = hi
        self.lo = lo
        self.recover_steps = recover_steps
        self.level = self.NORMAL
        #: level changes in either direction (a flat-overload trace makes
        #: at most 3 — the oscillation check the policy tests pin)
        self.transitions = 0
        #: observations spent at any degraded (non-normal) level
        self.steps_degraded = 0
        self._calm = 0

    @property
    def level_name(self) -> str:
        """Human-readable name of the current level."""
        return self.LEVEL_NAMES[self.level]

    def observe(self, pressure: float) -> int:
        """Feed one pressure sample in [0, 1]; returns the (possibly
        changed) level for the engine step about to run."""
        if pressure > self.hi:
            self._calm = 0
            if self.level < self.SHED:
                self.level += 1
                self.transitions += 1
        elif pressure < self.lo:
            self._calm += 1
            if self._calm >= self.recover_steps \
                    and self.level > self.NORMAL:
                self.level -= 1
                self.transitions += 1
                self._calm = 0
        else:
            self._calm = 0
        if self.level:
            self.steps_degraded += 1
        return self.level
