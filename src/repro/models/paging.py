"""Jax-traceable paged-KV primitives: gather a slot view, scatter new rows.

The serve tier's paged allocator (:mod:`repro.serve.cache`) stores every
positional state leaf as a *physical page pool* — shape
``(num_pages, page_size, ...)`` per layer — plus a per-slot ``(max_pages,)``
int32 page-index vector (the page table).  The model layer consumes that
layout through exactly two primitives:

* :func:`gather_pages` — materialize the contiguous ``(B, S, ...)`` view a
  decode/prefill step attends over, by gathering each slot's pages.  The
  gathered view is *bit-identical* to the dense cache at every attendable
  position, so the attention math downstream is unchanged.
* :func:`scatter_token_rows` — write the step's ``C`` freshly-computed rows
  per slot back into the pool at their physical ``(page, offset)``
  coordinates, computed in-graph from the page table.  Only the written
  rows move; untouched (possibly *shared*, refcounted) pages are never
  rewritten, which is what makes zero-copy prefix sharing safe: a slot can
  read a page it does not own, but its writes always land in pages the
  serve engine allocated (or copy-on-write'd) for that slot alone.

Physical page 0 is reserved by the allocator as a scratch page: idle decode
lanes point their whole table row at it, so their unconditional (discarded)
KV writes can never corrupt a retired-but-reusable slot's pages.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

__all__ = ["gather_pages", "gather_pages_dequant", "scatter_token_rows"]


def gather_pages(pool: jnp.ndarray, pages: jnp.ndarray) -> jnp.ndarray:
    """Gather per-slot pages into a contiguous sequence view.

    Args:
      pool: one state leaf's physical pool, ``(num_pages, page_size, ...)``.
      pages: ``(B, n_pages)`` int32 page table — row ``b`` lists the
        physical page backing each of slot ``b``'s logical pages.

    Returns:
      ``(B, n_pages * page_size, ...)`` view; position ``s`` of slot ``b``
      reads ``pool[pages[b, s // page_size], s % page_size]``.
    """
    v = jnp.take(pool, pages, axis=0)        # (B, n_pages, page, ...)
    return v.reshape((v.shape[0], v.shape[1] * v.shape[2]) + v.shape[3:])


def gather_pages_dequant(pool: jnp.ndarray, scale_pool: jnp.ndarray,
                         pages: jnp.ndarray, out_dtype) -> jnp.ndarray:
    """Dequantizing :func:`gather_pages`: gather integer code pages and
    their per-row scale pages, and return the float slot view the split-K
    attend consumes.

    Args:
      pool: quantized code pool, ``(num_pages, page_size, ..., D)`` int8
        (8-bit) or ``(num_pages, page_size, ..., D/2)`` packed uint8
        (4-bit) — the dtype tags the bit width
        (:func:`repro.models.quant_kv.kv_bits`).
      scale_pool: ``(num_pages, page_size, ...)`` fp32 per-row scales
        (same pooled layout, one trailing axis fewer).
      pages: ``(B, n_pages)`` int32 page table.
      out_dtype: dtype of the dequantized view (the attention compute
        dtype).

    Returns:
      ``(B, n_pages * page_size, ..., D)`` dequantized view — the
      quantized analogue of :func:`gather_pages`'s contiguous output.
    """
    from repro.models.quant_kv import dequantize_rows
    return dequantize_rows(gather_pages(pool, pages),
                           gather_pages(scale_pool, pages), out_dtype)


def scatter_token_rows(pool: jnp.ndarray, pages: jnp.ndarray,
                       rows: jnp.ndarray, pos: jnp.ndarray,
                       nvalid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Write ``C`` new rows per slot into the pool through the page table.

    Args:
      pool: one state leaf's physical pool, ``(num_pages, page_size, ...)``.
      pages: ``(B, n_pages)`` int32 page table.
      rows: ``(B, C, ...)`` rows to write (cast to the pool dtype).
      pos: int32 sequence positions of the rows — ``(B, C)`` per-slot, or
        ``(C,)`` shared across slots (broadcast, mirroring
        ``batched_cache_write``'s scalar/vector contract); each maps to
        physical coordinates ``(pages[b, pos // page_size],
        pos % page_size)``.
      nvalid: optional ``(B,)`` int32 per-slot count of valid rows.  Row
        ``j`` of slot ``b`` is written only when ``j < nvalid[b]`` (and its
        position lies inside the table); invalid rows are redirected to the
        reserved scratch page 0, whose contents are never read.  This is
        the write-masking speculative verification relies on: draft lanes
        beyond a slot's proposed length must not touch real pages.

    Returns:
      The pool with exactly the addressed valid rows replaced.  The
      caller (the serve engine) guarantees no two *live* slots address the
      same physical page, so duplicate scatter targets only arise on the
      shared scratch page, whose contents are never read.
    """
    page = pool.shape[1]
    n_pages = pages.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        pos = jnp.broadcast_to(pos[None], rows.shape[:2])
    lp = pos // page                                      # (B, C)
    off = pos % page
    in_range = lp < n_pages
    phys = jnp.take_along_axis(pages, jnp.minimum(lp, n_pages - 1), axis=1)
    if nvalid is not None:
        c = rows.shape[1]
        in_range &= jnp.arange(c, dtype=jnp.int32)[None] < \
            jnp.asarray(nvalid, jnp.int32)[:, None]
    phys = jnp.where(in_range, phys, 0)                   # 0 = scratch
    return pool.at[phys, off].set(rows.astype(pool.dtype))
