"""Paper Tables 1a/1b/1c + Table 2: carry bounds vs exhaustive arithmetic.

Reproduces every row of the paper's tables (including the hex entries) and
cross-checks theory columns (C_actual, C_UB, tight bound) against exact
bigint arithmetic, then sweeps a wider (N, M, k) grid.
"""
from __future__ import annotations

from repro.core import carry as ct

from benchmarks.common import Row, print_rows, section

# (k, N, M) rows as printed in the paper
TABLE_1A = [(10, 2, 1), (10, 4, 1), (16, 10, 1), (16, 15, 1)]
TABLE_1B = [(2, 5, 1), (2, 7, 1), (10, 11, 1), (10, 18, 1), (16, 20, 1),
            (16, 33, 1)]
TABLE_1C = [(2, 4, 1), (2, 12, 1), (10, 20, 1), (10, 50, 1), (16, 16, 1),
            (16, 48, 1)]
TABLE_2 = [(2, 2, 3), (2, 4, 3), (2, 7, 3), (2, 7, 5), (2, 10, 3),
           (2, 64, 3), (10, 2, 3), (10, 4, 3), (10, 10, 3), (10, 15, 4),
           (10, 1112, 3), (16, 2, 3), (16, 4, 3), (16, 18, 3), (16, 65520, 2)]


def _row(k: int, n: int, m: int) -> Row:
    z = ct.max_total_sum(n, m, k)                   # all operands = k^m - 1
    c_act, s = ct.max_carry_multicolumn(n, m, k)
    ub = ct.carry_upper_bound(n)
    tight = ct.tight_carry_bound(n, k)
    assert z == c_act * k ** m + s
    assert c_act <= ub, (k, n, m)
    if m == 1:
        # the paper's tight forms (N-1 / N-n / N-1-n) are 1-column results
        assert c_act == ct.exact_max_carry_1col(n, k) == tight <= ub
    return {"k": k, "N": n, "M": m, "Z_max": z, "C_actual": c_act,
            "S": s, "C_tight": tight, "C_UB(N-1)": ub,
            "carry_digits": ct.carry_digits(n, m, k),
            "result_digits": ct.result_digits(n, m, k)}


def run() -> dict:
    tables = {}
    for name, title, spec in (
            ("table_1a", "Table 1a (N < k): 1-column carry bounds", TABLE_1A),
            ("table_1b", "Table 1b (N > k)", TABLE_1B),
            ("table_1c", "Table 1c (N = nk)", TABLE_1C),
            ("table_2", "Table 2 (multi-column)", TABLE_2)):
        section(title)
        tables[name] = [_row(*t) for t in spec]
        print_rows(tables[name])

    # wide sweep: theory == brute force everywhere
    checked = 0
    for k in (2, 3, 8, 10, 16):
        for n in (2, 3, 4, 5, 7, 15, 16, 17, 31, 64, 100):
            for m in (1, 2, 3, 4, 8):
                _row(k, n, m)
                checked += 1
    print(f"\nsweep: {checked} (k,N,M) cells checked against bigint "
          f"arithmetic — all bounds hold")
    return {"cells_checked": checked, **tables}


if __name__ == "__main__":
    run()
